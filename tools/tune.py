#!/usr/bin/env python
"""Fleet tuning-cache CLI (DESIGN.md §14): show / merge / export.

A fleet of workers each autotunes into its own ``TuningCache`` JSON
(entries keyed ``<machine.tuning_key>|<mode>|<desc-cache-key>``, each
record carrying the measured ``us`` and a ``ts`` wall-clock stamp).
This tool unions those files into one warm-start cache that serving
processes preload via ``configure(tuning_cache_preload=...)`` — read
only, zero autotune stalls.

Commands::

    python tools/tune.py show  cache.json [--machine PREFIX]
    python tools/tune.py merge out.json in1.json in2.json [...]
    python tools/tune.py export in.json out.json --machine PREFIX

Merge policy: union by entry key (machine tuning-key + execution mode +
descriptor cache key); on collision the record with the NEWEST ``ts``
wins (records without a stamp lose to any stamped record).  Entries from
network-calibrated machines never collide with uncalibrated ones — the
``+net`` tuning-key suffix keeps them apart (DESIGN.md §14).

Deliberately stdlib-only (no jax import): runs instantly on login nodes
and in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import Dict, List

CACHE_VERSION = 1


def load_entries(path: str) -> Dict[str, dict]:
    """Entries of one tuning-cache file; raises on a malformed file (the
    CLI should fail loudly where the engine degrades silently)."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or not isinstance(data.get("entries"), dict):
        raise ValueError(f"{path}: not a tuning-cache file")
    return data["entries"]


def merge_entries(caches: List[Dict[str, dict]]) -> Dict[str, dict]:
    """Union entry dicts; on key collision the newest ``ts`` wins."""
    out: Dict[str, dict] = {}
    for entries in caches:
        for key, rec in entries.items():
            old = out.get(key)
            if old is None or float(rec.get("ts", 0)) >= float(
                    old.get("ts", 0)):
                out[key] = rec
    return out


def write_cache(path: str, entries: Dict[str, dict]) -> None:
    """Atomic write in the engine's on-disk format."""
    payload = {"version": CACHE_VERSION, "entries": entries}
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tuning.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def filter_entries(entries: Dict[str, dict],
                   machine_prefix: str) -> Dict[str, dict]:
    """Entries whose machine tuning-key starts with ``machine_prefix``
    (``calibrated_host`` matches both ``calibrated_host`` and
    ``calibrated_host+net``; use the full ``+net`` form to select only
    network-calibrated records)."""
    return {k: v for k, v in entries.items()
            if k.split("|", 1)[0].startswith(machine_prefix)}


def _cmd_show(args) -> int:
    entries = load_entries(args.cache)
    if args.machine:
        entries = filter_entries(entries, args.machine)
    for key in sorted(entries):
        rec = entries[key]
        print(f"{key}\n    family={rec.get('family')} "
              f"us={rec.get('us')} ts={rec.get('ts', '-')} "
              f"fused={rec.get('fused', '-')} comm={rec.get('comm', '-')}")
    print(f"# {len(entries)} entries", file=sys.stderr)
    return 0


def _cmd_merge(args) -> int:
    caches = [load_entries(p) for p in args.inputs]
    merged = merge_entries(caches)
    write_cache(args.out, merged)
    total = sum(len(c) for c in caches)
    print(f"merged {len(args.inputs)} files ({total} entries) -> "
          f"{args.out} ({len(merged)} entries)", file=sys.stderr)
    return 0


def _cmd_export(args) -> int:
    entries = load_entries(args.cache)
    kept = filter_entries(entries, args.machine) if args.machine else entries
    write_cache(args.out, kept)
    print(f"exported {len(kept)}/{len(entries)} entries -> {args.out}",
          file=sys.stderr)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("show", help="list a cache's entries")
    p.add_argument("cache")
    p.add_argument("--machine", default=None,
                   help="filter by machine tuning-key prefix")
    p.set_defaults(fn=_cmd_show)
    p = sub.add_parser("merge", help="union caches, newest timing wins")
    p.add_argument("out")
    p.add_argument("inputs", nargs="+")
    p.set_defaults(fn=_cmd_merge)
    p = sub.add_parser("export", help="filter a cache to one machine")
    p.add_argument("cache")
    p.add_argument("out")
    p.add_argument("--machine", default=None,
                   help="machine tuning-key prefix to keep")
    p.set_defaults(fn=_cmd_export)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
