#!/usr/bin/env python
"""Fleet tuning-cache CLI (DESIGN.md §14): show / merge / export.

A fleet of workers each autotunes into its own ``TuningCache`` JSON
(entries keyed ``<machine.tuning_key>|<mode>|<desc-cache-key>``, each
record carrying the measured ``us`` and a ``ts`` wall-clock stamp).
This tool unions those files into one warm-start cache that serving
processes preload via ``configure(tuning_cache_preload=...)`` — read
only, zero autotune stalls.

Commands::

    python tools/tune.py show  cache.json [--machine PREFIX]
    python tools/tune.py merge out.json in1.json in2.json [...]
    python tools/tune.py export in.json out.json --machine PREFIX
    python tools/tune.py refit in1.json [in2.json ...] --out model.json

Merge policy: union by entry key (machine tuning-key + execution mode +
descriptor cache key); on collision the record with the NEWEST ``ts``
wins (records without a stamp lose to any stamped record).  Entries from
network-calibrated machines never collide with uncalibrated ones — the
``+net`` tuning-key suffix keeps them apart (DESIGN.md §14).

``refit`` closes the measure→model loop (DESIGN.md §15): it regresses
the merged fleet timings back onto the base ``MachineModel``'s cost
coefficients and writes a versioned refit-model JSON (provenance
fingerprint included) that ``configure(refit_model=...)`` /
``calibrate(refit=...)`` overlay at load time — the analytical tier
then ranks with fleet-fitted constants and its ``tuning_key`` grows a
``+refit`` suffix so records never mix with probe-only machines.

Deliberately stdlib-only (no jax import) for show/merge/export: they
run instantly on login nodes and in CI.  ``refit`` alone imports
``repro.core`` (numpy fit) lazily.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import Dict, List

CACHE_VERSION = 1


def load_entries(path: str) -> Dict[str, dict]:
    """Entries of one tuning-cache file; raises on a malformed file (the
    CLI should fail loudly where the engine degrades silently)."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or not isinstance(data.get("entries"), dict):
        raise ValueError(f"{path}: not a tuning-cache file")
    return data["entries"]


def merge_entries(caches: List[Dict[str, dict]]) -> Dict[str, dict]:
    """Union entry dicts; on key collision the newest ``ts`` wins."""
    out: Dict[str, dict] = {}
    for entries in caches:
        for key, rec in entries.items():
            old = out.get(key)
            if old is None or float(rec.get("ts", 0)) >= float(
                    old.get("ts", 0)):
                out[key] = rec
    return out


def write_cache(path: str, entries: Dict[str, dict]) -> None:
    """Atomic write in the engine's on-disk format."""
    payload = {"version": CACHE_VERSION, "entries": entries}
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tuning.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def filter_entries(entries: Dict[str, dict],
                   machine_prefix: str) -> Dict[str, dict]:
    """Entries whose machine tuning-key starts with ``machine_prefix``
    (``calibrated_host`` matches both ``calibrated_host`` and
    ``calibrated_host+net``; use the full ``+net`` form to select only
    network-calibrated records)."""
    return {k: v for k, v in entries.items()
            if k.split("|", 1)[0].startswith(machine_prefix)}


def _cmd_show(args) -> int:
    entries = load_entries(args.cache)
    if args.machine:
        entries = filter_entries(entries, args.machine)
    for key in sorted(entries):
        rec = entries[key]
        print(f"{key}\n    family={rec.get('family')} "
              f"us={rec.get('us')} ts={rec.get('ts', '-')} "
              f"fused={rec.get('fused', '-')} comm={rec.get('comm', '-')}")
    print(f"# {len(entries)} entries", file=sys.stderr)
    return 0


def _cmd_merge(args) -> int:
    caches = [load_entries(p) for p in args.inputs]
    merged = merge_entries(caches)
    write_cache(args.out, merged)
    total = sum(len(c) for c in caches)
    print(f"merged {len(args.inputs)} files ({total} entries) -> "
          f"{args.out} ({len(merged)} entries)", file=sys.stderr)
    return 0


def _cmd_export(args) -> int:
    entries = load_entries(args.cache)
    kept = filter_entries(entries, args.machine) if args.machine else entries
    write_cache(args.out, kept)
    print(f"exported {len(kept)}/{len(entries)} entries -> {args.out}",
          file=sys.stderr)
    return 0


def _cmd_refit(args) -> int:
    # Lazy heavy import: only the refit subcommand needs repro.core.
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "src"))
    from repro.core import refit as _refit
    from repro.core.machine import get_machine
    merged = merge_entries([load_entries(p) for p in args.inputs])
    base = get_machine(args.base)
    try:
        model = _refit.fit_cache_entries(
            merged, base, machine=args.machine or None,
            mode=None if args.mode == "any" else args.mode)
    except ValueError as e:
        print(f"refit failed: {e}", file=sys.stderr)
        return 1
    _refit.save_refit_model(args.out, model)
    res = model["residual_us"]
    print(f"refit {model['entries']} entries (skipped "
          f"{model['skipped']}) -> {args.out}\n"
          f"  fingerprint={model['fingerprint']} fitted="
          f"{','.join(model['fitted'])}\n"
          f"  residual_us before={res['before']} after={res['after']}",
          file=sys.stderr)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("show", help="list a cache's entries")
    p.add_argument("cache")
    p.add_argument("--machine", default=None,
                   help="filter by machine tuning-key prefix")
    p.set_defaults(fn=_cmd_show)
    p = sub.add_parser("merge", help="union caches, newest timing wins")
    p.add_argument("out")
    p.add_argument("inputs", nargs="+")
    p.set_defaults(fn=_cmd_merge)
    p = sub.add_parser("export", help="filter a cache to one machine")
    p.add_argument("cache")
    p.add_argument("out")
    p.add_argument("--machine", default=None,
                   help="machine tuning-key prefix to keep")
    p.set_defaults(fn=_cmd_export)
    p = sub.add_parser(
        "refit", help="fit MachineModel coefficients from cache timings")
    p.add_argument("inputs", nargs="+",
                   help="tuning-cache files (merged before fitting)")
    p.add_argument("--out", required=True,
                   help="refit-model JSON to write")
    p.add_argument("--machine", default=None,
                   help="filter by machine tuning-key prefix")
    p.add_argument("--mode", default="any",
                   choices=("any", "interpret", "compiled"),
                   help="restrict to one execution mode")
    p.add_argument("--base", default="tpu_v5e",
                   help="base machine model to refit (default tpu_v5e)")
    p.set_defaults(fn=_cmd_refit)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
