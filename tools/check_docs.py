#!/usr/bin/env python3
"""Docs consistency gate (run by tools/check.sh and CI).

Four contracts across the doc surfaces:

  * every ``DESIGN.md §n`` cited in a ``src/`` docstring (or in README.md)
    must resolve to a real ``## §n`` section of DESIGN.md — stale section
    numbers rot silently otherwise;
  * README.md must only name things that exist: local markdown links,
    repo paths in backticks, dotted ``repro.*`` module references, and
    the imports inside fenced python snippets (attribute-verified when
    the package is importable, file-verified when it is not);
  * every exported ``src/repro/core`` symbol (public top-level class or
    function) must carry a docstring — the engine is the system's public
    API and an undocumented export is a regression;
  * DESIGN.md §10-§12 (the schedule-layer, backward-passes and
    serving-runtime chapters) must together name every kernel family
    the engine registers — forward families in §10, ``*_bwd`` families
    in §11, the decode family in §12 — the family lists drift
    otherwise;
  * DESIGN.md §12 must keep naming the serving-runtime surface it
    documents (scheduler → pages → decode schedule → single launch) —
    the chapter drifts from the runtime otherwise;
  * DESIGN.md §13 must keep naming the low-precision surface (quant
    spec → scale tables → fused dequant epilogue → W8A16 codec →
    KV-int8 pools → quant benchmark), with the same two-sided
    existence check;
  * DESIGN.md §14 must keep naming the mesh-planning surface
    (interconnect probes → calibrated network model → mesh descriptors
    → comm-charged arbitration → expert-parallel dispatch → fleet
    tuning CLI → mesh benchmark), same two-sided existence check;
  * DESIGN.md §15 must keep naming the fleet-tuning / warm-start
    surface (offline coefficient refit → refit-model overlay → tune CLI
    verb → descriptor manifest → engine warmup API → warm-start config
    knob → benchmark tuning-cache artifact), same two-sided check.

Stdlib only (``ast``-based, no imports of the package needed for the
docstring gate); exits non-zero with one line per violation.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Repo directories README paths may point into.
_PATH_ROOTS = ("src/", "examples/", "benchmarks/", "tools/", "tests/")


def design_sections() -> set:
    design = (ROOT / "DESIGN.md").read_text()
    return set(re.findall(r"^##\s+§(\d+)", design, flags=re.M))


def check_design_refs(sections: set) -> list:
    errors = []
    files = sorted((ROOT / "src").rglob("*.py")) + [ROOT / "README.md"]
    for path in files:
        text = path.read_text()
        for n in re.findall(r"DESIGN\.md\s+§(\d+)", text):
            if n not in sections:
                errors.append(f"{path.relative_to(ROOT)}: cites DESIGN.md "
                              f"§{n}, which has no '## §{n}' section")
    return errors


def _module_exists(dotted: str) -> bool:
    """True if some prefix of ``dotted`` (>= 2 components) is a module or
    package under src/ — trailing components may be attributes."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        base = ROOT / "src" / pathlib.Path(*parts[:end])
        if base.with_suffix(".py").exists() or (base / "__init__.py").exists():
            return True
    return False


def _import_names(module: str, names: list) -> list:
    """Verify ``from module import names`` resolves; empty list if the
    environment can't import (no jax): file existence already checked."""
    sys.path.insert(0, str(ROOT / "src"))
    try:
        import importlib
        mod = importlib.import_module(module)
    except Exception:
        return []
    finally:
        sys.path.pop(0)
    return [n for n in names if not hasattr(mod, n)]


def check_readme() -> list:
    readme = (ROOT / "README.md").read_text()
    errors = []

    # 1. local markdown links: [text](PAPER.md), [x](DESIGN.md#anchor) etc.
    for target in re.findall(r"\]\(([^)]+)\)", readme):
        if "://" in target:
            continue
        target = target.split("#", 1)[0]  # file part; anchors not checked
        if target and not (ROOT / target).exists():
            errors.append(f"README.md: broken link target {target!r}")

    # 2. backticked repo paths.
    for token in re.findall(r"`([^`\s]+)`", readme):
        if token.startswith(_PATH_ROOTS) and not (ROOT / token).exists():
            errors.append(f"README.md: names missing path {token!r}")

    # 3. dotted repro.* module references anywhere in the doc.
    for dotted in sorted(set(re.findall(r"\brepro(?:\.\w+)+", readme))):
        if not _module_exists(dotted):
            errors.append(f"README.md: names missing module {dotted!r}")

    # 4. fenced snippets: python imports resolve, bash scripts exist.
    for block in re.findall(r"```(?:python|bash)\n(.*?)```", readme, re.S):
        for module, imported in re.findall(
                r"^from\s+([\w.]+)\s+import\s+([\w, ]+)", block, re.M):
            if not _module_exists(module):
                errors.append(f"README.md: snippet imports missing module "
                              f"{module!r}")
                continue
            for name in _import_names(module, re.split(r"[,\s]+",
                                                       imported.strip())):
                errors.append(f"README.md: snippet imports {name!r}, not an "
                              f"attribute of {module!r}")
        for script in re.findall(r"python\s+(?:-m\s+)?([\w/.-]+\.py)", block):
            if not (ROOT / script).exists():
                errors.append(f"README.md: snippet runs missing script "
                              f"{script!r}")
    return errors


def check_core_docstrings() -> list:
    """Every exported (public, top-level) class/function under
    ``src/repro/core`` carries a docstring.  Modules with ``__all__``
    restrict the check to it; otherwise every non-underscore top-level
    class/def counts as exported."""
    errors = []
    for path in sorted((ROOT / "src" / "repro" / "core").glob("*.py")):
        tree = ast.parse(path.read_text())
        exported = None
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and getattr(node.targets[0], "id", None) == "__all__"):
                exported = {getattr(e, "value", None)
                            for e in getattr(node.value, "elts", [])}
        for node in tree.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                continue
            if node.name.startswith("_"):
                continue
            if exported is not None and node.name not in exported:
                continue
            if ast.get_docstring(node) is None:
                errors.append(
                    f"{path.relative_to(ROOT)}: exported symbol "
                    f"{node.name!r} has no docstring")
    return errors


def engine_families() -> list:
    """Kernel family names the engine registers, parsed from the
    ``_FAMILY_MODULES`` table in ``core/engine.py`` source."""
    text = (ROOT / "src" / "repro" / "core" / "engine.py").read_text()
    m = re.search(r"_FAMILY_MODULES\s*=\s*\{(.*?)\}", text, re.S)
    if not m:
        return []
    return re.findall(r'"(\w+)"\s*:\s*"repro\.kernels', m.group(1))


def _design_section(design: str, num: str) -> str:
    m = re.search(rf"^## §{num}\b.*?(?=^## §|\Z)", design, re.S | re.M)
    return m.group(0) if m else ""


def check_design_families() -> list:
    """DESIGN.md §10-§12 together name every registered kernel family
    (forward families in the schedule-layer chapter, ``*_bwd`` families
    in the backward-passes chapter, the decode family in the serving
    chapter)."""
    design = (ROOT / "DESIGN.md").read_text()
    section = ""
    missing_chapters = []
    for num in ("10", "11", "12"):
        chapter = _design_section(design, num)
        if chapter:
            section += chapter
        else:
            missing_chapters.append(
                f"DESIGN.md: no '## §{num}' section (the family matrices "
                f"live in §10-§12)")
    if missing_chapters:
        return missing_chapters
    families = engine_families()
    if not families:
        return ["tools/check_docs.py: could not parse _FAMILY_MODULES "
                "from core/engine.py"]
    return [f"DESIGN.md §10-§12: registered family {fam!r} missing from "
            f"the family lists" for fam in families if fam not in section]


# The serving-runtime surface DESIGN.md §12 documents.  Each entry is
# (name-that-must-appear-in-§12, repo file that must still define it) —
# both sides checked, so the gate catches the chapter rotting away from
# the runtime AND the runtime rotting away from the chapter.
_SERVING_SURFACE = (
    ("ContinuousBatchingEngine", "src/repro/runtime/batching.py"),
    ("PagePool", "src/repro/runtime/pages.py"),
    ("DecodeTileSchedule", "src/repro/core/schedule.py"),
    ("make_paged_serve_step", "src/repro/runtime/steps.py"),
    ("BENCH_serve.json", "benchmarks/serve_trace.py"),
)


def check_design_serving() -> list:
    """DESIGN.md §12 drift gate: the serving chapter must name each
    layer of the runtime (scheduler, page allocator, decode schedule,
    paged step, benchmark artifact), and each named symbol must still
    exist in the file that owns it."""
    design = (ROOT / "DESIGN.md").read_text()
    chapter = _design_section(design, "12")
    if not chapter:
        return ["DESIGN.md: no '## §12' section (the serving-runtime "
                "chapter)"]
    errors = []
    for name, rel in _SERVING_SURFACE:
        if name not in chapter:
            errors.append(f"DESIGN.md §12: serving surface {name!r} "
                          f"missing from the chapter")
        src = ROOT / rel
        if not src.exists() or name.split(".")[0] not in src.read_text():
            errors.append(f"{rel}: no longer defines {name!r} named by "
                          f"DESIGN.md §12")
    return errors


# The low-precision surface DESIGN.md §13 documents.  Same contract as
# _SERVING_SURFACE: the chapter must name each layer of the quant axis,
# and each named symbol must still exist in the file that owns it.
_QUANT_SURFACE = (
    ("QuantSpec", "src/repro/core/descriptor.py"),
    ("QUANT_TILE", "src/repro/core/schedule.py"),
    ("apply_epilogue", "src/repro/kernels/epilogue.py"),
    ("QuantizedTensor", "src/repro/optim/compression.py"),
    ("quantize_model", "src/repro/optim/compression.py"),
    ("kv_quant", "src/repro/models/attention.py"),
    ("BENCH_quant.json", "benchmarks/quant_gemm.py"),
)


def check_design_quant() -> list:
    """DESIGN.md §13 drift gate: the quant chapter must name each layer
    of the low-precision axis (spec, scale tables, fused epilogue,
    weight-only codec, KV-int8 pools, benchmark artifact), and each
    named symbol must still exist in the file that owns it."""
    design = (ROOT / "DESIGN.md").read_text()
    chapter = _design_section(design, "13")
    if not chapter:
        return ["DESIGN.md: no '## §13' section (the low-precision "
                "chapter)"]
    errors = []
    for name, rel in _QUANT_SURFACE:
        if name not in chapter:
            errors.append(f"DESIGN.md §13: quant surface {name!r} "
                          f"missing from the chapter")
        src = ROOT / rel
        if not src.exists() or name.split(".")[0] not in src.read_text():
            errors.append(f"{rel}: no longer defines {name!r} named by "
                          f"DESIGN.md §13")
    return errors


# The mesh-planning surface DESIGN.md §14 documents.  Same contract:
# the chapter must name each layer of the mesh axis (probes, calibrated
# network model, mesh spec, strategy arbitration, EP execution, fleet
# cache CLI, benchmark artifact), each still defined by its owning file.
_MESH_SURFACE = (
    ("probe_all_gather", "src/repro/core/microbench.py"),
    ("collective_seconds", "src/repro/core/machine.py"),
    ("MeshSpec", "src/repro/core/descriptor.py"),
    ("mesh_comm_events", "src/repro/core/blocking.py"),
    ("count_comm", "src/repro/core/engine.py"),
    ("expert_parallel_grouped_gemm", "src/repro/kernels/grouped_gemm/ops.py"),
    ("tuning_cache_preload", "src/repro/core/config.py"),
    ("BENCH_mesh.json", "benchmarks/mesh_overlap.py"),
)


def check_design_mesh() -> list:
    """DESIGN.md §14 drift gate: the mesh chapter must name each layer
    of the mesh-planning axis (interconnect probes, calibrated network
    model, mesh descriptors, comm-charged arbitration, expert-parallel
    execution, fleet tuning CLI, benchmark artifact), and each named
    symbol must still exist in the file that owns it."""
    design = (ROOT / "DESIGN.md").read_text()
    chapter = _design_section(design, "14")
    if not chapter:
        return ["DESIGN.md: no '## §14' section (the mesh-planning "
                "chapter)"]
    errors = []
    for name, rel in _MESH_SURFACE:
        if name not in chapter:
            errors.append(f"DESIGN.md §14: mesh surface {name!r} "
                          f"missing from the chapter")
        src = ROOT / rel
        if not src.exists() or name.split(".")[0] not in src.read_text():
            errors.append(f"{rel}: no longer defines {name!r} named by "
                          f"DESIGN.md §14")
    return errors


# The fleet-tuning / warm-start surface DESIGN.md §15 documents.  Same
# contract: the chapter must name each layer of the offline loop (refit
# fit, model overlay loader, refit CLI, descriptor manifest round-trip,
# engine warm-start API + config knob, benchmark cache artifact), each
# still defined by its owning file.
_WARMSTART_SURFACE = (
    ("fit_cache_entries", "src/repro/core/refit.py"),
    ("load_refit_model", "src/repro/core/machine.py"),
    ("refit", "tools/tune.py"),
    ("descriptor_from_cache_key", "src/repro/core/descriptor.py"),
    ("save_manifest", "src/repro/core/warmstart.py"),
    ("warmup", "src/repro/core/engine.py"),
    ("warm_start", "src/repro/core/config.py"),
    ("BENCH_tuning_cache.json", "benchmarks/fig89_gemm_sweep.py"),
)


def check_design_warmstart() -> list:
    """DESIGN.md §15 drift gate: the fleet-tuning chapter must name each
    layer of the offline refit + AOT warm-start loop (coefficient fit,
    refit-model loader, tune CLI verb, descriptor manifest, engine
    warmup API, config knob, benchmark cache artifact), and each named
    symbol must still exist in the file that owns it."""
    design = (ROOT / "DESIGN.md").read_text()
    chapter = _design_section(design, "15")
    if not chapter:
        return ["DESIGN.md: no '## §15' section (the fleet-tuning / "
                "warm-start chapter)"]
    errors = []
    for name, rel in _WARMSTART_SURFACE:
        if name not in chapter:
            errors.append(f"DESIGN.md §15: warm-start surface {name!r} "
                          f"missing from the chapter")
        src = ROOT / rel
        if not src.exists() or name.split(".")[0] not in src.read_text():
            errors.append(f"{rel}: no longer defines {name!r} named by "
                          f"DESIGN.md §15")
    return errors


def main() -> int:
    sections = design_sections()
    if not sections:
        print("check_docs: DESIGN.md has no '## §n' sections", file=sys.stderr)
        return 1
    errors = (check_design_refs(sections) + check_readme()
              + check_core_docstrings() + check_design_families()
              + check_design_serving() + check_design_quant()
              + check_design_mesh() + check_design_warmstart())
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        n_refs = sum(len(re.findall(r"DESIGN\.md\s+§\d+", p.read_text()))
                     for p in (ROOT / "src").rglob("*.py"))
        print(f"check_docs: OK ({len(sections)} DESIGN sections, "
              f"{n_refs} src citations, README verified, core docstrings "
              f"+ §10-§12 family lists + §12 serving + §13 quant "
              f"+ §14 mesh + §15 warm-start surfaces verified)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
