#!/usr/bin/env python3
"""Docs consistency gate (run by tools/check.sh and CI).

Two contracts, one per doc surface:

  * every ``DESIGN.md §n`` cited in a ``src/`` docstring (or in README.md)
    must resolve to a real ``## §n`` section of DESIGN.md — stale section
    numbers rot silently otherwise;
  * README.md must only name things that exist: local markdown links,
    repo paths in backticks, dotted ``repro.*`` module references, and
    the imports inside fenced python snippets (attribute-verified when
    the package is importable, file-verified when it is not).

Stdlib only; exits non-zero with one line per violation.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Repo directories README paths may point into.
_PATH_ROOTS = ("src/", "examples/", "benchmarks/", "tools/", "tests/")


def design_sections() -> set:
    design = (ROOT / "DESIGN.md").read_text()
    return set(re.findall(r"^##\s+§(\d+)", design, flags=re.M))


def check_design_refs(sections: set) -> list:
    errors = []
    files = sorted((ROOT / "src").rglob("*.py")) + [ROOT / "README.md"]
    for path in files:
        text = path.read_text()
        for n in re.findall(r"DESIGN\.md\s+§(\d+)", text):
            if n not in sections:
                errors.append(f"{path.relative_to(ROOT)}: cites DESIGN.md "
                              f"§{n}, which has no '## §{n}' section")
    return errors


def _module_exists(dotted: str) -> bool:
    """True if some prefix of ``dotted`` (>= 2 components) is a module or
    package under src/ — trailing components may be attributes."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        base = ROOT / "src" / pathlib.Path(*parts[:end])
        if base.with_suffix(".py").exists() or (base / "__init__.py").exists():
            return True
    return False


def _import_names(module: str, names: list) -> list:
    """Verify ``from module import names`` resolves; empty list if the
    environment can't import (no jax): file existence already checked."""
    sys.path.insert(0, str(ROOT / "src"))
    try:
        import importlib
        mod = importlib.import_module(module)
    except Exception:
        return []
    finally:
        sys.path.pop(0)
    return [n for n in names if not hasattr(mod, n)]


def check_readme() -> list:
    readme = (ROOT / "README.md").read_text()
    errors = []

    # 1. local markdown links: [text](PAPER.md), [x](DESIGN.md#anchor) etc.
    for target in re.findall(r"\]\(([^)]+)\)", readme):
        if "://" in target:
            continue
        target = target.split("#", 1)[0]  # file part; anchors not checked
        if target and not (ROOT / target).exists():
            errors.append(f"README.md: broken link target {target!r}")

    # 2. backticked repo paths.
    for token in re.findall(r"`([^`\s]+)`", readme):
        if token.startswith(_PATH_ROOTS) and not (ROOT / token).exists():
            errors.append(f"README.md: names missing path {token!r}")

    # 3. dotted repro.* module references anywhere in the doc.
    for dotted in sorted(set(re.findall(r"\brepro(?:\.\w+)+", readme))):
        if not _module_exists(dotted):
            errors.append(f"README.md: names missing module {dotted!r}")

    # 4. fenced snippets: python imports resolve, bash scripts exist.
    for block in re.findall(r"```(?:python|bash)\n(.*?)```", readme, re.S):
        for module, imported in re.findall(
                r"^from\s+([\w.]+)\s+import\s+([\w, ]+)", block, re.M):
            if not _module_exists(module):
                errors.append(f"README.md: snippet imports missing module "
                              f"{module!r}")
                continue
            for name in _import_names(module, re.split(r"[,\s]+",
                                                       imported.strip())):
                errors.append(f"README.md: snippet imports {name!r}, not an "
                              f"attribute of {module!r}")
        for script in re.findall(r"python\s+(?:-m\s+)?([\w/.-]+\.py)", block):
            if not (ROOT / script).exists():
                errors.append(f"README.md: snippet runs missing script "
                              f"{script!r}")
    return errors


def main() -> int:
    sections = design_sections()
    if not sections:
        print("check_docs: DESIGN.md has no '## §n' sections", file=sys.stderr)
        return 1
    errors = check_design_refs(sections) + check_readme()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        n_refs = sum(len(re.findall(r"DESIGN\.md\s+§\d+", p.read_text()))
                     for p in (ROOT / "src").rglob("*.py"))
        print(f"check_docs: OK ({len(sections)} DESIGN sections, "
              f"{n_refs} src citations, README verified)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
