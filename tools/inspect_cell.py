"""Dump the largest tensors + collectives from one dry-run cell's HLO."""
import os, sys, re, collections
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, "src")

_B = {"bf16":2,"f32":4,"f16":2,"f64":8,"s32":4,"u32":4,"s8":1,"u8":1,"pred":1,"s64":8,"u64":8,"s16":2,"u16":2}

def main(arch, shape, mesh):
    from repro.launch import dryrun as dr
    import repro.launch.dryrun  # ensure env
    import jax
    from repro.configs import get_config, shape_for, input_specs
    # reuse run_cell internals up to lowering by calling run_cell with save=False
    # then re-lower here to capture hlo: simpler to copy logic via run_cell's compiled
    rec = None
    # monkeypatch to capture hlo
    import repro.launch.dryrun as D
    orig = D.parse_collectives
    captured = {}
    def cap(hlo):
        captured["hlo"] = hlo
        return orig(hlo)
    D.parse_collectives = cap
    rec = D.run_cell(arch, shape, mesh, save=False)
    hlo = captured["hlo"]
    sizes = []
    for m in re.finditer(r"(bf16|f32|f16|f64|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]+)\]", hlo):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","): n *= int(d)
        sizes.append((n*_B[dt], f"{dt}[{dims}]"))
    cnt = collections.Counter(s for _, s in sizes)
    uniq = {}
    for b, s in sizes:
        uniq[s] = b
    top = sorted(uniq.items(), key=lambda kv: -kv[1])[:15]
    print("\nTop tensor shapes (unique, per-device):")
    for s, b in top:
        print(f"  {b/2**30:8.2f} GiB  {s}   x{cnt[s]} occurrences")
    print("\nLargest collectives:")
    coll = []
    for line in hlo.splitlines():
        m = re.search(r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\(", line)
        if m:
            b = 0
            for mm in re.finditer(r"(bf16|f32|f16|f64|s32|u32|s8|u8|pred)\[([0-9,]*)\]", m.group(1)):
                n = 1
                if mm.group(2):
                    for d in mm.group(2).split(","): n *= int(d)
                b += n*_B[mm.group(1)]
            coll.append((b, m.group(2), line.strip()[:180]))
    for b, op, line in sorted(coll, key=lambda x: -x[0])[:12]:
        print(f"  {b/2**30:8.3f} GiB {op}: {line[:150]}")

if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], sys.argv[3] if len(sys.argv) > 3 else "pod")
