#!/usr/bin/env bash
# Tier-1 gate: byte-compile the package, check docs consistency
# (DESIGN.md section references, README module/path references, core
# docstrings, §10 family list), execute the quickstart/serving examples
# (so they can't drift from the engine API), and run the test suite.
# Usage: bash tools/check.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

python -m compileall -q src
python tools/check_docs.py
python examples/quickstart.py > /dev/null
python examples/serve_batched.py > /dev/null
python -m pytest -q
